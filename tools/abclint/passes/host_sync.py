"""Pass 2 — host-sync leaks (ABC2xx).

The second serving invariant is DEVICE RESIDENCE: on the defer path the
host reads exactly one count scalar per tier transition, through the
byte-metered ``core.cascade._fetch``, and payload bytes only ever cross a
boundary inside a metered ``serve.transport.Transport`` hop.  PR 3 proved
this dynamically with ``jax.transfer_guard`` tests at a handful of call
sites; this pass is the static twin, repo-wide over the serving hot path.

Scope: ``src/repro/serve/`` and ``src/repro/core/cascade.py`` — the two
places where an implicit device→host transfer is a correctness-of-cost
bug, not a style nit.  ``serve/transport.py`` is whitelisted wholesale
(it IS the metered boundary), as is the body of ``_fetch`` itself.

ABC201  ``.item()`` — the classic silent scalar sync.
ABC202  ``int()``/``float()``/``bool()`` over a call/subscript expression
        (the usual shape is ``bool(np.asarray(x)[0])``).  Conversions of
        ``_fetch(...)`` results, ``len``/``min``/``max``/``sum``/shape
        arithmetic and friends are host-side and exempt.
ABC203  ``np.asarray``/``np.array`` — numpy coercion of a jax array is an
        unmetered device→host gather.  Wrapping an explicit fetch
        (``np.asarray(jax.device_get(...))`` / ``_fetch(...)``) is exempt;
        everything else is either a genuine leak (fix: route through
        ``_fetch``) or host-side list handling (pragma/baseline it, with
        the reason).
ABC204  ``jax.device_get`` outside ``_fetch``/``Transport`` — explicit,
        but unmetered: byte accounting can't see it.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from tools.abclint import astutil
from tools.abclint.engine import FileContext, Finding, Pass

RULES = {
    "ABC201": ".item() on an array (silent device->host scalar sync)",
    "ABC202": "int()/float()/bool() over an array expression (unmetered "
              "host sync — convert a _fetch'd value instead)",
    "ABC203": "np.asarray/np.array on the serving hot path (unmetered "
              "device->host gather — route through cascade._fetch)",
    "ABC204": "jax.device_get outside the metered _fetch/Transport path",
}

#: files where crossing the boundary is the module's JOB
_FILE_WHITELIST = ("src/repro/serve/transport.py",)
#: functions whose body is the blessed explicit-fetch implementation
_FUNC_WHITELIST = {"_fetch", "host_fetch"}

#: call roots whose results are host values (safe to int()/float()/bool())
_HOST_CALLS = {
    "_fetch", "cascade._fetch", "host_fetch", "cascade.host_fetch", "len",
    "min", "max", "sum", "round", "abs", "sorted", "time.perf_counter",
    "time.monotonic", "np.prod", "host_fetch_stats",
}
_HOST_ATTR_TAILS = (".shape", ".size", ".ndim")


def in_scope(relpath: str) -> bool:
    if relpath in _FILE_WHITELIST:
        return False
    return (
        relpath.startswith("src/repro/serve/")
        or relpath == "src/repro/core/cascade.py"
    )


def _host_rooted(node: ast.AST) -> bool:
    """Conversion argument recognizably produces a HOST value."""
    if isinstance(node, ast.Call):
        d = astutil.call_name(node)
        if d is not None and (
            d in _HOST_CALLS or d.split(".")[-1] in {
                n.split(".")[-1] for n in _HOST_CALLS
            }
        ):
            return True
        return False
    if isinstance(node, ast.Subscript):
        base = node.value
        d = astutil.dotted(base)
        if d is not None and d.endswith(_HOST_ATTR_TAILS[0][1:]):
            return True
        if isinstance(base, ast.Attribute) and (
            "." + base.attr
        ) in _HOST_ATTR_TAILS:
            return True
        return _host_rooted(base)
    if isinstance(node, ast.Attribute):
        return ("." + node.attr) in _HOST_ATTR_TAILS
    if isinstance(node, ast.BinOp):
        return _host_rooted(node.left) and _host_rooted(node.right)
    return False


def _explicit_fetch(node: ast.AST) -> bool:
    """The expression wraps an explicit fetch (device_get/_fetch/.result())."""
    for call in astutil.calls_in(node):
        d = astutil.call_name(call)
        if d is None:
            continue
        tail = d.split(".")[-1]
        if tail in ("device_get", "_fetch", "host_fetch", "result"):
            return True
    return False


def _whitelisted(stack: List[ast.AST]) -> bool:
    return any(
        getattr(fn, "name", None) in _FUNC_WHITELIST for fn in stack
    )


def check_file(ctx: FileContext) -> List[Finding]:
    findings: List[Finding] = []
    for node, stack in astutil.enclosing_functions(ctx.tree):
        if not isinstance(node, ast.Call) or _whitelisted(stack):
            continue
        d = astutil.call_name(node)
        if d is None:
            # method call on an arbitrary expression: catch .item()
            if (
                isinstance(node.func, ast.Attribute)
                and node.func.attr == "item"
            ):
                findings.append(
                    ctx.finding(
                        "ABC201", node,
                        ".item() syncs device->host unmetered — fetch via "
                        "cascade._fetch and index the host array",
                    )
                )
            continue
        tail = d.split(".")[-1]
        if tail == "item":
            findings.append(
                ctx.finding(
                    "ABC201", node,
                    ".item() syncs device->host unmetered — fetch via "
                    "cascade._fetch and index the host array",
                )
            )
        elif d in ("int", "float", "bool") and node.args:
            arg = node.args[0]
            if (
                isinstance(arg, (ast.Call, ast.Subscript))
                and not _host_rooted(arg)
                and not _explicit_fetch(arg)
            ):
                findings.append(
                    ctx.finding(
                        "ABC202", node,
                        f"{d}() over an array expression is an unmetered "
                        "host sync — fetch through cascade._fetch first",
                    )
                )
        elif d in ("np.asarray", "np.array", "numpy.asarray", "numpy.array"):
            if node.args and not _explicit_fetch(node.args[0]):
                findings.append(
                    ctx.finding(
                        "ABC203", node,
                        f"{d} on the serving hot path — if the argument "
                        "can be a jax array this is an unmetered gather; "
                        "route through cascade._fetch (or justify via "
                        "pragma/baseline if it is host-side data)",
                    )
                )
        elif tail == "device_get":
            findings.append(
                ctx.finding(
                    "ABC204", node,
                    "jax.device_get outside _fetch/Transport — explicit "
                    "but unmetered; byte accounting cannot see it",
                )
            )
    return findings


PASS = Pass(
    name="host_sync", rules=RULES, check_file=check_file, scope=in_scope
)
