"""Pass 3 — determinism (ABC3xx).

The third serving invariant is BIT-DETERMINISM: greedy cascades generate
bitwise-identically across processes, hosts, and transport overlap modes
(DESIGN.md §8's equivalence claim).  PR 1's worst bug was exactly this
class — ``hash(bytes)`` is PYTHONHASHSEED-salted per process, so identical
member generations voted differently across runs until voting moved to a
stable crc32 digest.

Scope: ``src/repro/core/`` and ``src/repro/serve/`` — the code whose
outputs the equivalence tests assert bitwise-equal.

ABC301  builtin ``hash()`` — process-salted for str/bytes; never feed it
        into anything that crosses a process boundary.  Use a stable
        digest (``zlib.crc32``, ``hashlib``).
ABC302  iterating a ``set`` (or ``set()``/set-comprehension result) —
        iteration order is hash order; results that depend on it are not
        reproducible.  ``sorted(set(...))`` is exempt (order restored).
ABC303  wall-clock / seed-free randomness feeding computation:
        ``time.time``/``datetime.now`` and the seed-free global RNGs
        (``random.*``, legacy ``np.random.*``, argless
        ``np.random.default_rng()``).  Monotonic METERING clocks
        (``time.perf_counter``/``monotonic``) and ``time.sleep`` are
        exempt — they time work, they don't steer it; seeded
        ``default_rng(seed)`` / ``jax.random`` keys are the blessed
        randomness.
"""
from __future__ import annotations

import ast
from typing import List

from tools.abclint import astutil
from tools.abclint.engine import FileContext, Finding, Pass

RULES = {
    "ABC301": "builtin hash() (PYTHONHASHSEED-salted: irreproducible "
              "across processes — use zlib.crc32/hashlib)",
    "ABC302": "iteration over a set (hash-ordered: result order is not "
              "reproducible — sort it first)",
    "ABC303": "wall-clock or seed-free randomness feeding computation "
              "(time.time/random.*/legacy np.random/argless default_rng)",
}

_CLOCK_BANNED = {"time.time", "datetime.now", "datetime.utcnow",
                 "datetime.datetime.now", "datetime.datetime.utcnow"}
_NP_LEGACY = {
    "rand", "randn", "randint", "random", "random_sample", "choice",
    "permutation", "shuffle", "standard_normal", "uniform", "normal",
    "seed",
}
_PY_RANDOM = {
    "random.random", "random.randint", "random.choice", "random.shuffle",
    "random.uniform", "random.sample", "random.randrange", "random.seed",
    "random.gauss",
}


def in_scope(relpath: str) -> bool:
    return relpath.startswith(("src/repro/core/", "src/repro/serve/"))


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        d = astutil.call_name(node)
        return d in ("set", "frozenset")
    return False


def check_file(ctx: FileContext) -> List[Finding]:
    findings: List[Finding] = []
    sorted_args = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) and astutil.call_name(node) == "sorted":
            for a in node.args:
                sorted_args.add(id(a))
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            d = astutil.call_name(node)
            if d == "hash":
                findings.append(
                    ctx.finding(
                        "ABC301", node,
                        "hash() is salted per process — identical inputs "
                        "digest differently across runs; use zlib.crc32 "
                        "(serve.cascade_server.stable_digest) or hashlib",
                    )
                )
            elif d in _CLOCK_BANNED or d in _PY_RANDOM:
                findings.append(
                    ctx.finding(
                        "ABC303", node,
                        f"{d}() in deterministic scope — wall clock / "
                        "seed-free randomness makes runs irreproducible; "
                        "meter with time.perf_counter, randomize with a "
                        "seeded rng",
                    )
                )
            elif d is not None and d.startswith("np.random."):
                tail = d.split(".")[-1]
                if tail in _NP_LEGACY:
                    findings.append(
                        ctx.finding(
                            "ABC303", node,
                            f"{d} uses numpy's seed-free global generator "
                            "— use np.random.default_rng(seed)",
                        )
                    )
                elif tail == "default_rng" and not node.args:
                    findings.append(
                        ctx.finding(
                            "ABC303", node,
                            "np.random.default_rng() without a seed is "
                            "entropy-seeded — pass an explicit seed",
                        )
                    )
        iters = []
        if isinstance(node, (ast.For, ast.AsyncFor)):
            iters.append(node.iter)
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                               ast.DictComp)):
            iters.extend(g.iter for g in node.generators)
        elif isinstance(node, ast.Call) and astutil.call_name(node) in (
            "list", "tuple", "enumerate"
        ):
            iters.extend(node.args[:1])
        for it in iters:
            if _is_set_expr(it) and id(it) not in sorted_args:
                findings.append(
                    ctx.finding(
                        "ABC302", it,
                        "iterating a set in deterministic scope — order is "
                        "hash order; wrap in sorted() before anything that "
                        "feeds results",
                    )
                )
    return findings


PASS = Pass(
    name="determinism", rules=RULES, check_file=check_file, scope=in_scope
)
