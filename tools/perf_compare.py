"""Before/after table for EXPERIMENTS.md §Perf: legacy baselines
(experiments/perf/legacy) vs the optimized final sweep (experiments/dryrun).

    PYTHONPATH=src python tools/perf_compare.py
"""
import glob
import json
import os

CELLS = [
    ("llama4-maverick-400b-a17b", "decode_32k"),
    ("zamba2-2.7b", "decode_32k"),
    ("mixtral-8x22b", "train_4k"),
    ("mixtral-8x22b", "decode_32k"),
    ("command-r-plus-104b", "decode_32k"),
    ("qwen2.5-3b", "decode_32k"),
]


def get(d, arch, shape):
    f = os.path.join(d, f"{arch}__{shape}__pod16x16.json")
    return json.load(open(f))["roofline"]


def ratio(a, b):
    return f"{a/b:.1f}×" if b else "—"


def main():
    print("| cell | t_compute before → after | t_memory before → after | t_collective before → after |")
    print("|---|---|---|---|")
    for arch, shape in CELLS:
        try:
            b = get("experiments/perf/legacy", arch, shape)
            a = get("experiments/dryrun", arch, shape)
        except FileNotFoundError:
            continue
        def cell(key):
            bb, aa = b[key], a[key]
            r = f" ({bb/aa:.1f}×)" if aa and bb / max(aa, 1e-12) >= 1.05 else ""
            return f"{bb:.3g} s → {aa:.3g} s{r}"
        print(f"| {arch} × {shape} | {cell('t_compute_s')} | {cell('t_memory_s')} | {cell('t_collective_s')} |")


if __name__ == "__main__":
    main()
