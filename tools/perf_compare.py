"""Perf comparison, two modes.

Default (no args): before/after table for EXPERIMENTS.md §Perf — legacy
roofline baselines (experiments/perf/legacy) vs the optimized final sweep
(experiments/dryrun).

    PYTHONPATH=src python tools/perf_compare.py

Bench gate (``--bench``): compare a ``benchmarks.run --json`` results file
against a committed baseline and exit nonzero on step-time regressions —
CI's bench-smoke job runs this so the perf trajectory accumulates and a
slow hot path cannot merge silently.

    PYTHONPATH=src python tools/perf_compare.py \
        --bench BENCH_smoke.json --baseline benchmarks/baselines/BENCH_smoke.json

A row regresses when ``current > baseline * max_regression + slack_us``;
the multiplicative factor absorbs runner-speed differences between the
machine that seeded the baseline and the CI host, the additive slack keeps
microsecond-scale rows out of the noise.  Rows missing from the current
run (a bench was deleted or errored) fail too; new rows not yet in the
baseline are reported but never fail — refresh the baseline to adopt them.
Rows whose BASELINE derived column carries a ``gate=off`` tag (e.g. the
interpret-mode starts sweeps, whose wall clock swings several-x on shared
runners) must still be present and non-NaN but their timing is
informational only.

Derived KEYS gate too: every ``k=v`` key in a baseline row's derived
column must still appear in the current run's derived column — that is
how the registry-backed report fields (``serve.request_latency_s.p50_ms``
and friends) are pinned: a bench that silently stops emitting them fails
here, not in review.  DESIGN.md §11 renamed the old unnamespaced stats
keys (``admit_ms``, ``hop_bytes``, ...) to fully-qualified registry metric
names; ``NAME_MAP`` translates old→new so committed baselines keep gating
without a refresh.
"""
import argparse
import glob
import json
import os
import sys

#: old unnamespaced derived keys -> fully-qualified registry metric names
#: (DESIGN.md §11).  A baseline key found here is satisfied by the new name.
NAME_MAP = {
    "admit_ms": "slot_stream.admit_ms",
    "paged_peak_pages": "paging.pool_occupancy.peak",
    "efold_prefix_saved_mb": "paging.shared_prefix_saved_mb",
    "link_time_hidden_ms": "transport.edge0_cloud0.hidden_ms",
    "hop_bytes": "transport.loopback.bytes",
}

CELLS = [
    ("llama4-maverick-400b-a17b", "decode_32k"),
    ("zamba2-2.7b", "decode_32k"),
    ("mixtral-8x22b", "train_4k"),
    ("mixtral-8x22b", "decode_32k"),
    ("command-r-plus-104b", "decode_32k"),
    ("qwen2.5-3b", "decode_32k"),
]


def get(d, arch, shape):
    f = os.path.join(d, f"{arch}__{shape}__pod16x16.json")
    return json.load(open(f))["roofline"]


def ratio(a, b):
    return f"{a/b:.1f}×" if b else "—"


def roofline_table():
    print("| cell | t_compute before → after | t_memory before → after | t_collective before → after |")
    print("|---|---|---|---|")
    for arch, shape in CELLS:
        try:
            b = get("experiments/perf/legacy", arch, shape)
            a = get("experiments/dryrun", arch, shape)
        except FileNotFoundError:
            continue
        def cell(key):
            bb, aa = b[key], a[key]
            r = f" ({bb/aa:.1f}×)" if aa and bb / max(aa, 1e-12) >= 1.05 else ""
            return f"{bb:.3g} s → {aa:.3g} s{r}"
        print(f"| {arch} × {shape} | {cell('t_compute_s')} | {cell('t_memory_s')} | {cell('t_collective_s')} |")


def derived_keys(derived):
    """``k=v;k2=v2`` -> {k, k2} (the ``gate`` tag is control, not data)."""
    return {
        kv.split("=", 1)[0]
        for kv in str(derived).split(";")
        if "=" in kv and kv.split("=", 1)[0] != "gate"
    }


def compare_bench(bench_path, baseline_path, max_regression, slack_us):
    cur = json.load(open(bench_path))
    base = json.load(open(baseline_path))
    cur_rows, base_rows = cur.get("rows", {}), base.get("rows", {})
    failures = []
    if cur.get("failed"):
        failures.append(f"benches errored in the current run: {cur['failed']}")
    print(f"{'bench':46s} {'base_us':>12s} {'cur_us':>12s} {'ratio':>7s}")
    for name in sorted(base_rows):
        b_us = base_rows[name]["us_per_call"]
        c = cur_rows.get(name)
        if c is None:
            failures.append(f"{name}: present in baseline, missing from current run")
            print(f"{name:46s} {b_us:12.1f} {'MISSING':>12s}")
            continue
        c_us = c["us_per_call"]
        if c_us != c_us:  # NaN — the bench printed an ERROR row
            failures.append(f"{name}: current run is NaN (bench errored)")
            print(f"{name:46s} {b_us:12.1f} {'nan':>12s}")
            continue
        cur_keys = derived_keys(c.get("derived", ""))
        lost = {
            k for k in derived_keys(base_rows[name].get("derived", ""))
            if k not in cur_keys and NAME_MAP.get(k) not in cur_keys
        }
        if lost:
            failures.append(
                f"{name}: derived keys vanished from the current run: "
                f"{sorted(lost)} (registry-backed report fields gate on "
                "presence; see NAME_MAP for renames)"
            )
        r = c_us / b_us if b_us else float("inf")
        if "gate=off" in base_rows[name].get("derived", ""):
            print(f"{name:46s} {b_us:12.1f} {c_us:12.1f} {r:7.2f}  (gate=off)")
            continue
        flag = ""
        if c_us > b_us * max_regression + slack_us:
            failures.append(
                f"{name}: {c_us:.1f}us vs baseline {b_us:.1f}us "
                f"(x{r:.2f} > x{max_regression:g} + {slack_us:g}us slack)"
            )
            flag = "  << REGRESSION"
        print(f"{name:46s} {b_us:12.1f} {c_us:12.1f} {r:7.2f}{flag}")
    for name in sorted(set(cur_rows) - set(base_rows)):
        print(f"{name:46s} {'(new)':>12s} {cur_rows[name]['us_per_call']:12.1f}")
    if failures:
        print("\nFAIL: step-time regressions vs committed baseline:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nOK: no step-time regressions vs committed baseline")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=None, metavar="JSON",
                    help="benchmarks.run --json output to gate")
    ap.add_argument("--baseline", default="benchmarks/baselines/BENCH_smoke.json")
    ap.add_argument("--max-regression", type=float, default=2.5,
                    help="fail when current > baseline * this + slack")
    ap.add_argument("--slack-us", type=float, default=200.0)
    args = ap.parse_args()
    if args.bench:
        sys.exit(compare_bench(args.bench, args.baseline, args.max_regression, args.slack_us))
    roofline_table()


if __name__ == "__main__":
    main()
